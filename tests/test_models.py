"""Per-architecture smoke tests (assignment deliverable f): every assigned
arch instantiates its REDUCED-family config and runs one forward + one decode
step on CPU, asserting shapes and finiteness. Train steps for one arch per
family. Mamba2/mLSTM chunked forms are validated against their sequential
recurrences."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import forward, init_model, serve, steps
from repro.models.ssm import chunked_linear_recurrence
from repro.optim import adamw_init

B, S = 2, 16


def _batch(cfg, rng_seed=0):
    key = jax.random.PRNGKey(rng_seed)
    batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab)}
    if cfg.input_mode == "embeds":
        if cfg.is_encdec:
            batch["embeds"] = jnp.ones((B, S // cfg.enc_len_ratio, cfg.d_model), jnp.bfloat16)
        else:
            batch = {"embeds": jnp.ones((B, S, cfg.d_model), jnp.bfloat16)}
    if cfg.rope_kind == "mrope":
        batch["positions3"] = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (3, B, S))
    batch["labels"] = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    return batch


# the recurrent-family smokes compile large scan bodies, and moonshot's MoE
# smoke is the other compile heavyweight (deepseek keeps the family covered
# in the fast tier) — slow tier
_HEAVY_SMOKE = {"zamba2_7b", "xlstm_125m", "seamless_m4t_medium",
                "moonshot_v1_16b_a3b"}


@pytest.mark.parametrize(
    "arch", [pytest.param(a, marks=pytest.mark.slow) if a in _HEAVY_SMOKE else a
             for a in ARCH_IDS])
def test_arch_smoke_forward_and_decode(arch):
    cfg = get_config(arch).smoke()
    params = init_model(cfg, jax.random.PRNGKey(0))
    logits, aux = forward(params, cfg, _batch(cfg))
    assert logits.shape == (B, S, cfg.vocab_padded)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
    assert bool(jnp.isfinite(aux))

    cache = serve.init_cache(cfg, B, S)
    dl, cache2 = serve.decode(params, cfg, cache,
                              {"tokens": jnp.zeros((B, 1), jnp.int32)})
    assert dl.shape == (B, 1, cfg.vocab_padded)
    assert bool(jnp.all(jnp.isfinite(dl.astype(jnp.float32))))
    assert int(cache2["index"]) == 1


@pytest.mark.parametrize(
    "arch", ["tinyllama_1_1b"] + [pytest.param(a, marks=pytest.mark.slow)
                                  for a in ("deepseek_moe_16b", "zamba2_7b",
                                            "xlstm_125m", "seamless_m4t_medium")])
def test_arch_train_step(arch):
    cfg = get_config(arch).smoke()
    params = init_model(cfg, jax.random.PRNGKey(0))
    opt = adamw_init(params)
    ts = jax.jit(steps.make_train_step(cfg))
    # step 5: inside warmup but lr > 0 (step 0 has lr == 0 by schedule)
    params, opt, m = ts(params, opt, _batch(cfg), jnp.asarray(5, jnp.int32))
    assert np.isfinite(float(m["loss"]))
    assert float(m["loss"]) < 2.0 * np.log(cfg.vocab)   # sane init loss
    assert float(m["lr"]) > 0.0
    # one more step on the same batch must change the loss (update applied)
    _, _, m2 = ts(params, opt, _batch(cfg), jnp.asarray(6, jnp.int32))
    assert float(m2["loss"]) != float(m["loss"])


@pytest.mark.slow
def test_decode_matches_forward_dense():
    """Prefill logits at each position == step-by-step decode logits (the
    KV-cache correctness contract)."""
    cfg = get_config("tinyllama_1_1b").smoke().replace(remat=False)
    params = init_model(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0, cfg.vocab)
    full_logits, _ = forward(params, cfg, {"tokens": toks})

    cache = serve.init_cache(cfg, B, S)
    for t in range(S):
        dl, cache = serve.decode(params, cfg, cache, {"tokens": toks[:, t:t + 1]})
        np.testing.assert_allclose(
            np.asarray(dl[:, 0], np.float32),
            np.asarray(full_logits[:, t], np.float32),
            rtol=0.15, atol=0.15)  # bf16 accumulation-order tolerance


@pytest.mark.slow
def test_decode_matches_forward_ssm():
    """Chunked mLSTM/sLSTM training form == recurrent decode form."""
    cfg = get_config("xlstm_125m").smoke().replace(remat=False)
    params = init_model(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0, cfg.vocab)
    full_logits, _ = forward(params, cfg, {"tokens": toks})
    cache = serve.init_cache(cfg, B, S)
    for t in range(S):
        dl, cache = serve.decode(params, cfg, cache, {"tokens": toks[:, t:t + 1]})
    np.testing.assert_allclose(
        np.asarray(dl[:, 0], np.float32),
        np.asarray(full_logits[:, -1], np.float32), rtol=0.15, atol=0.15)


@pytest.mark.slow
def test_decode_matches_forward_hybrid():
    cfg = get_config("zamba2_7b").smoke().replace(remat=False)
    params = init_model(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0, cfg.vocab)
    full_logits, _ = forward(params, cfg, {"tokens": toks})
    cache = serve.init_cache(cfg, B, S)
    for t in range(S):
        dl, cache = serve.decode(params, cfg, cache, {"tokens": toks[:, t:t + 1]})
    np.testing.assert_allclose(
        np.asarray(dl[:, 0], np.float32),
        np.asarray(full_logits[:, -1], np.float32), rtol=0.15, atol=0.15)


def test_chunked_recurrence_matches_sequential():
    rng = np.random.default_rng(0)
    Bs, T, H, N, P = 2, 24, 2, 4, 3
    log_a = -np.abs(rng.normal(size=(Bs, T, H))).astype(np.float32) * 0.2
    Bm = rng.normal(size=(Bs, T, H, N)).astype(np.float32)
    Cm = rng.normal(size=(Bs, T, H, N)).astype(np.float32)
    X = rng.normal(size=(Bs, T, H, P)).astype(np.float32)
    h = np.zeros((Bs, H, P, N), np.float32)
    Yref = np.zeros((Bs, T, H, P), np.float32)
    for t in range(T):
        a = np.exp(log_a[:, t])
        h = a[..., None, None] * h + np.einsum("bhp,bhn->bhpn", X[:, t], Bm[:, t])
        Yref[:, t] = np.einsum("bhn,bhpn->bhp", Cm[:, t], h)
    for chunk in (4, 8, 24):
        Y, hf = chunked_linear_recurrence(
            jnp.asarray(log_a), jnp.asarray(Bm), jnp.asarray(Cm), jnp.asarray(X), chunk)
        np.testing.assert_allclose(np.asarray(Y), Yref, rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.asarray(hf), h, rtol=1e-4, atol=1e-4)


def test_chunked_attention_matches_plain():
    from repro.models.attention import _chunked_attention, _plain_attention
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.normal(size=(2, 32, 4, 16)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(2, 32, 4, 16)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(2, 32, 4, 16)).astype(np.float32))
    plain = _plain_attention(q, k, v, causal=True)
    for chunk in (8, 16, 32):
        ch = _chunked_attention(q, k, v, causal=True, chunk=chunk)
        np.testing.assert_allclose(np.asarray(ch), np.asarray(plain), rtol=2e-4, atol=2e-4)


@pytest.mark.slow
def test_quantized_serve_forward_all_families():
    """Tensorizer W8A8 params run through forward for one arch per family."""
    from repro.core import tensorizer as tz
    from repro.launch.serve import _quant_predicate
    for arch in ("tinyllama_1_1b", "deepseek_moe_16b", "zamba2_7b",
                 "xlstm_125m", "qwen2_vl_2b"):
        cfg = get_config(arch).smoke().replace(quantize="serve")
        params = init_model(cfg, jax.random.PRNGKey(0))
        qparams = tz.quantize_params(params, predicate=_quant_predicate)
        logits, _ = forward(qparams, cfg, _batch(cfg))
        assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32)))), arch


def test_param_count_sane():
    cfg = get_config("tinyllama_1_1b")
    n = cfg.param_count()
    assert 0.9e9 < n < 1.4e9          # ~1.1B
    moe = get_config("moonshot_v1_16b_a3b")
    assert moe.param_count() > 10e9
    assert moe.active_param_count() < 0.35 * moe.param_count()
