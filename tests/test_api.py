"""HTTP serve API invariants (serving/api.py over Engine | Router):

  * bit-identity — tokens served over HTTP (streamed or not) are exactly the
                   tokens a direct Engine.submit produces, and a seeded
                   sampled completion returns the same stream on every call
  * streaming    — SSE events arrive in order (index 0..n-1), the terminal
                   frame carries finish_reason + n_tokens, and the stream
                   closes with ``data: [DONE]``
  * non-generative — /v1/embeddings returns the d_model-dim hidden state the
                   direct Engine.embed computes; /v1/classify softmaxes the
                   candidate token logits into a distribution
  * door contract — missing prompt / bad sampling params / unknown routes
                   are 4xx JSON errors, never hung sockets; /healthz and
                   /v1/stats serve while traffic decodes

The server is booted in-process on port 0 (OS-assigned) with the session
mesh passed through — the serve loop thread must enter the mesh itself
because jax's active-mesh state is thread-local.
"""

import http.client
import json

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import init_model
from repro.serving import Engine, EngineConfig, serve_api

CFG = get_config("tinyllama-1.1b").smoke()
RNG = np.random.default_rng(31)


@pytest.fixture(scope="module")
def params():
    return init_model(CFG, jax.random.PRNGKey(0))


@pytest.fixture()
def api(params, mesh):
    """A fresh engine behind a port-0 API server, torn down per test."""
    eng = Engine(CFG, params, EngineConfig(max_slots=2, max_seq_len=32))
    srv = serve_api(eng, port=0, mesh=mesh)
    yield srv, eng
    srv.close()
    eng.close()


def _request(srv, method, path, body=None):
    conn = http.client.HTTPConnection("127.0.0.1", srv.port, timeout=60)
    payload = json.dumps(body).encode() if body is not None else None
    headers = {"Content-Type": "application/json"} if payload else {}
    conn.request(method, path, body=payload, headers=headers)
    resp = conn.getresponse()
    data = json.loads(resp.read().decode())
    conn.close()
    return resp.status, data


def _stream(srv, body):
    """POST a streaming completion, return the decoded SSE event list."""
    conn = http.client.HTTPConnection("127.0.0.1", srv.port, timeout=60)
    conn.request("POST", "/v1/completions",
                 body=json.dumps({**body, "stream": True}).encode(),
                 headers={"Content-Type": "application/json"})
    resp = conn.getresponse()
    assert resp.status == 200
    assert resp.getheader("Content-Type") == "text/event-stream"
    events = []
    for raw in resp.fp:
        line = raw.decode().strip()
        if not line.startswith("data: "):
            continue
        data = line[len("data: "):]
        if data == "[DONE]":
            break
        events.append(json.loads(data))
    conn.close()
    return events


def _prompt(n):
    return [int(t) for t in RNG.integers(0, CFG.vocab, (n,))]


def test_healthz_and_stats(api):
    srv, _ = api
    status, body = _request(srv, "GET", "/healthz")
    assert status == 200 and body == {"ok": True}
    status, body = _request(srv, "GET", "/v1/stats")
    assert status == 200
    assert body["submitted"] == 0
    status, _ = _request(srv, "GET", "/no/such/route")
    assert status == 404


def test_completion_matches_direct_engine(params, mesh):
    """The HTTP path is a transport, not a different decoder: greedy tokens
    over POST /v1/completions equal a direct Engine.submit bit for bit."""
    prompt = _prompt(6)
    ref_eng = Engine(CFG, params, EngineConfig(max_slots=2, max_seq_len=32))
    ref = ref_eng.submit(prompt, 8, strict=True)
    ref_eng.run_until_complete()
    expected = list(ref.tokens)
    ref_eng.close()

    eng = Engine(CFG, params, EngineConfig(max_slots=2, max_seq_len=32))
    srv = serve_api(eng, port=0, mesh=mesh)
    try:
        status, body = _request(srv, "POST", "/v1/completions",
                                {"prompt": prompt, "max_new_tokens": 8})
        assert status == 200
        assert body["tokens"] == expected
        assert body["finish_reason"] == "length"
    finally:
        srv.close()
        eng.close()


def test_sse_stream_orders_and_terminates(api):
    srv, _ = api
    prompt = _prompt(5)
    events = _stream(srv, {"prompt": prompt, "max_new_tokens": 6})
    *toks, done = events
    assert [e["index"] for e in toks] == list(range(6))
    assert done == {"done": True, "finish_reason": "length", "n_tokens": 6}
    # the streamed tokens equal the non-streamed ones for the same prompt
    status, body = _request(srv, "POST", "/v1/completions",
                            {"prompt": prompt, "max_new_tokens": 6})
    assert status == 200 and body["tokens"] == [e["token"] for e in toks]


def test_seeded_sampling_is_reproducible_over_http(api):
    """Same seed, same stream — the batch-invariance counter survives the
    HTTP hop, so retries and replays are exact."""
    srv, eng = api
    req = {"prompt": _prompt(6), "max_new_tokens": 8,
           "temperature": 0.8, "top_k": 20, "top_p": 0.95, "seed": 1234}
    status, first = _request(srv, "POST", "/v1/completions", req)
    assert status == 200
    status, again = _request(srv, "POST", "/v1/completions", req)
    assert status == 200
    assert first["tokens"] == again["tokens"]
    assert eng.metrics.sampled_tokens >= 16


def test_stop_sequence_over_http(api):
    srv, _ = api
    prompt = _prompt(5)
    status, full = _request(srv, "POST", "/v1/completions",
                            {"prompt": prompt, "max_new_tokens": 8})
    assert status == 200 and len(full["tokens"]) == 8
    stop = full["tokens"][2:4]
    status, cut = _request(srv, "POST", "/v1/completions",
                           {"prompt": prompt, "max_new_tokens": 8,
                            "stop": [stop]})
    assert status == 200
    assert cut["tokens"] == full["tokens"][:4]
    assert cut["finish_reason"] == "stop"


def test_logprobs_over_http(api):
    """``"logprobs": true`` adds each token's log-probability (and
    ``"top_logprobs": k`` its k alternatives) from the very logits row the
    token choice used — no second forward. Strictly opt-in: responses
    without the flag carry exactly the pre-logprobs fields."""
    srv, _ = api
    prompt = _prompt(6)
    base = {"prompt": prompt, "max_new_tokens": 6}
    status, plain = _request(srv, "POST", "/v1/completions", base)
    assert status == 200
    assert set(plain) == {"tokens", "finish_reason"}   # nothing uninvited

    status, lp = _request(srv, "POST", "/v1/completions",
                          {**base, "logprobs": True, "top_logprobs": 2})
    assert status == 200
    assert lp["tokens"] == plain["tokens"]             # observation-free
    assert len(lp["logprobs"]) == len(lp["tokens"])
    assert all(v <= 0.0 for v in lp["logprobs"])
    assert all(len(row) == 2 for row in lp["top_logprobs"])
    # greedy decode: the chosen token is the argmax, so it heads every top
    # row with its own log-probability
    for tok, l, row in zip(lp["tokens"], lp["logprobs"], lp["top_logprobs"]):
        assert row[0][0] == tok and abs(row[0][1] - l) < 1e-6

    # "logprobs": true alone -> per-token values only, no top_logprobs key
    status, only = _request(srv, "POST", "/v1/completions",
                            {**base, "logprobs": True})
    assert status == 200 and "top_logprobs" not in only
    assert only["logprobs"] == lp["logprobs"]

    # streamed events carry the same fields riding each token event...
    events = _stream(srv, {**base, "logprobs": True, "top_logprobs": 2})
    *toks_ev, done = events
    assert [e["token"] for e in toks_ev] == lp["tokens"]
    assert [e["logprob"] for e in toks_ev] == lp["logprobs"]
    assert [e["top_logprobs"] for e in toks_ev] == lp["top_logprobs"]
    # ...and are absent from streams that did not ask
    events = _stream(srv, base)
    assert all("logprob" not in e for e in events[:-1])


def test_embeddings_match_direct_embed(params, mesh):
    prompt = _prompt(7)
    ref_eng = Engine(CFG, params, EngineConfig(max_slots=2, max_seq_len=32))
    direct = ref_eng.embed(prompt)["embedding"]
    ref_eng.close()

    eng = Engine(CFG, params, EngineConfig(max_slots=2, max_seq_len=32))
    srv = serve_api(eng, port=0, mesh=mesh)
    try:
        status, body = _request(srv, "POST", "/v1/embeddings",
                                {"prompt": prompt})
        assert status == 200
        assert body["dim"] == CFG.d_model == len(body["embedding"])
        np.testing.assert_allclose(np.asarray(body["embedding"]),
                                   np.asarray(direct), rtol=1e-6)
        assert eng.metrics.embed_requests == 1
    finally:
        srv.close()
        eng.close()


def test_classify_is_a_distribution(api):
    srv, _ = api
    classes = [3, 17, 99]
    status, body = _request(srv, "POST", "/v1/classify",
                            {"prompt": _prompt(6), "classes": classes})
    assert status == 200
    assert body["classes"] == classes
    assert abs(sum(body["probs"]) - 1.0) < 1e-9
    assert body["top"] == classes[int(np.argmax(body["probs"]))]


def test_bad_requests_are_4xx(api):
    srv, _ = api
    status, body = _request(srv, "POST", "/v1/completions", {})
    assert status == 400 and "prompt" in body["error"]
    status, body = _request(srv, "POST", "/v1/completions",
                            {"prompt": _prompt(4), "temperature": -1.0})
    assert status == 400 and "temperature" in body["error"]
    status, body = _request(srv, "POST", "/v1/embeddings", {})
    assert status == 400
    status, body = _request(srv, "POST", "/v1/classify",
                            {"prompt": _prompt(4)})
    assert status == 400
    # over-budget requests hit the engine door -> strict QueueFull -> 400
    status, body = _request(srv, "POST", "/v1/completions",
                            {"prompt": _prompt(30), "max_new_tokens": 30})
    assert status == 400 and "rejected" in body["error"]
