"""Checkpointing (atomicity, integrity, async) + fault-tolerance planning."""

import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import AsyncCheckpointer, latest_step, load_checkpoint, save_checkpoint
from repro.ft import HeartbeatMonitor, plan_elastic_mesh


def _tree():
    return {"w": jnp.arange(12.0).reshape(3, 4),
            "opt": {"mu": jnp.ones((3, 4)), "step": jnp.asarray(7)}}


class TestCheckpoint:
    def test_roundtrip(self, tmp_path):
        t = _tree()
        save_checkpoint(tmp_path, 5, t)
        assert latest_step(tmp_path) == 5
        out = load_checkpoint(tmp_path, 5, t)
        jax.tree.map(lambda a, b: np.testing.assert_array_equal(np.asarray(a), np.asarray(b)), t, out)

    def test_incomplete_checkpoint_skipped(self, tmp_path):
        save_checkpoint(tmp_path, 5, _tree())
        # a torn save: directory without the _COMPLETE marker
        torn = tmp_path / "step_000000009"
        torn.mkdir()
        (torn / "manifest.json").write_text("{}")
        assert latest_step(tmp_path) == 5

    def test_corruption_detected(self, tmp_path):
        t = _tree()
        d = save_checkpoint(tmp_path, 3, t)
        # flip bytes in one leaf
        f = d / "arr_00000.npy"
        arr = np.load(f)
        arr += 1
        np.save(f, arr)
        with pytest.raises(IOError, match="crc"):
            load_checkpoint(tmp_path, 3, t)

    def test_async_checkpointer_and_gc(self, tmp_path):
        ck = AsyncCheckpointer(tmp_path, keep_last=2)
        t = _tree()
        for s in (1, 2, 3, 4):
            ck.save(s, t)
        ck.wait()
        steps = sorted(int(d.name.split("_")[1]) for d in tmp_path.iterdir()
                       if d.name.startswith("step_"))
        assert steps == [3, 4]

    def test_latest_none_when_empty(self, tmp_path):
        assert latest_step(tmp_path) is None
        assert latest_step(tmp_path / "missing") is None


class TestFaultTolerance:
    def test_heartbeat_detects_dead(self):
        clock = {"t": 0.0}
        mon = HeartbeatMonitor(["h0", "h1", "h2"], timeout_s=10,
                               clock=lambda: clock["t"])
        clock["t"] = 5.0
        mon.beat("h0")
        mon.beat("h1")
        clock["t"] = 12.0
        assert mon.dead_hosts() == ["h2"]
        assert set(mon.healthy_hosts()) == {"h0", "h1"}

    def test_straggler_detection(self):
        mon = HeartbeatMonitor(["h0", "h1", "h2", "h3"], straggler_factor=3.0)
        for h in ("h0", "h1", "h2"):
            for _ in range(5):
                mon.beat(h, step_latency_s=1.0)
        for _ in range(5):
            mon.beat("h3", step_latency_s=10.0)
        assert mon.stragglers() == ["h3"]

    def test_elastic_plan_preserves_model_parallel_and_batch(self):
        plan = plan_elastic_mesh(
            n_surviving_hosts=7, chips_per_host=32, model_parallel=16,
            old_data_parallel=16, global_batch=256)
        dp, mp = plan["mesh_shape"]
        assert mp == 16
        assert 256 % dp == 0
        assert plan["grad_accum"] * dp >= 16 // 2  # batch preserved via accum
        assert plan["chips_used"] <= 7 * 32

    def test_elastic_plan_fails_when_too_small(self):
        with pytest.raises(RuntimeError):
            plan_elastic_mesh(n_surviving_hosts=1, chips_per_host=8,
                              model_parallel=16, old_data_parallel=16,
                              global_batch=256)
